"""End-to-end multi-expert serving driver — the paper's headline scenario.

Builds a base model + several ComPEFT-compressed experts, then serves a
mixed batch of requests through the LRU expert cache, reporting swap bytes
vs the uncompressed baseline (paper Table 5 quantities).

    PYTHONPATH=src python examples/serve_experts.py [--experts 4] [--requests 12]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.peft import compress_expert, task_vector
from repro.peft.lora import _path_str
from repro.serve import (EngineConfig, ExpertStore, Request, ServeEngine,
                         uncompressed_baseline_bytes)

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--density", type=float, default=0.1)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2_5_3b", d_model=96, n_units=2)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))

    # expert library: base + per-task deltas, ComPEFT-compressed
    store = ExpertStore()
    for i in range(args.experts):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        tau = task_vector(base, ft)
        flat, _ = jax.tree_util.tree_flatten_with_path(tau)
        art = compress_expert(f"expert{i}", "full",
                              {_path_str(p): l for p, l in flat},
                              density=args.density, alpha=1.0)
        store.put(art)
        if i == 0:
            dense = uncompressed_baseline_bytes(art)
            print(f"expert artifact: {art.nbytes:,} B compressed vs "
                  f"{dense:,} B dense bf16 ({dense/art.nbytes:.1f}x)")

    engine = ServeEngine(api, RT, base, store,
                         EngineConfig(max_batch=4, cache_len=64,
                                      device_cache_bytes=1 << 26))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, expert=f"expert{i % args.experts}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 16),
                                       jnp.int32),
                    max_new_tokens=6)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    print(f"served {len(reqs)} requests across {args.experts} experts "
          f"in {dt:.1f}s")
    for r in reqs[:3]:
        print(f"  req{r.uid} [{r.expert}]: {r.out_tokens}")
    s = engine.swap_summary()
    print("swap stats:", {k: v for k, v in s.items()
                          if k in ('hits', 'misses', 'promotions',
                                   'store_to_host_bytes',
                                   'host_to_device_bytes', 'n_swaps',
                                   'n_waves', 'admitted', 'stack_builds')})
    dense_equiv = uncompressed_baseline_bytes(store.get("expert0")) * 2
    print(f"wire bytes per miss: {dense_equiv:,} dense f32 baseline vs "
          f"{s['store_to_host_bytes'] // max(s['misses'],1):,} compressed "
          f"(experts stay packed on device: "
          f"{s['host_to_device_bytes'] // max(s['misses'],1):,} B resident)")
    print("OK")


if __name__ == "__main__":
    main()
