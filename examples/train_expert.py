"""Train a LoRA expert, compress it with ComPEFT, save the Golomb
artifact, and verify the reconstructed expert — the full expert production
pipeline (paper §2 + §3.1 at CPU scale) on the ``repro.api`` facade.

    PYTHONPATH=src python examples/train_expert.py [--steps 60] [--task 1]
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro import api as capi
from repro.configs import get_smoke_config
from repro.data.pipeline import eval_loss, make_batch_for
from repro.models import Runtime, build
from repro.peft import LoraConfig, apply_lora, init_lora
from repro.train import LoopConfig, TrainConfig, make_train_step, train_loop

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--task", type=int, default=1)
    ap.add_argument("--density", type=float, default=0.1)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2_5_3b", d_model=96, n_units=3)
    api = build(cfg)
    print(f"model: {cfg.name}-smoke "
          f"({sum(x.size for x in jax.tree_util.tree_leaves(api.init(jax.random.PRNGKey(0)))):,} params)")

    # 1) brief base pretraining (task 0)
    tcfg = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=200)
    step_fn = jax.jit(make_train_step(api, RT, tcfg))
    lcfg = LoopConfig(total_steps=args.steps, seq_len=48, global_batch=8,
                      task_id=0, ckpt_dir=None, log_every=20)
    state, _ = train_loop(api, RT, tcfg, lcfg, step_fn)
    base = state["params"]

    # 2) LoRA fine-tune on the expert task
    lcfg_l = LoraConfig(rank=4, alpha=8.0)
    lora0 = init_lora(jax.random.PRNGKey(7), base, lcfg_l)

    def loss_fn(lp, batch):
        return api.loss_and_logits(apply_lora(base, lp, lcfg_l), batch, RT)[0]

    grad_fn = jax.jit(jax.grad(loss_fn))
    lora = lora0
    for s in range(args.steps):
        b = make_batch_for(cfg, s, 48, 8, task_id=args.task)
        lora = jax.tree_util.tree_map(lambda p, g: p - 0.5 * g, lora,
                                      grad_fn(lora, b))
        if s % 20 == 0:
            print(f"  lora step {s}: loss "
                  f"{float(loss_fn(lora, b)):.4f}")

    # 3) compress + save the expert artifact (Golomb wire format)
    out = os.path.join(tempfile.gettempdir(), "expert_task%d.npz" % args.task)
    expert = capi.compress(lora0, lora, name=f"task{args.task}", kind="lora",
                           density=args.density, alpha=1.0)
    stats = expert.save(out)
    print(f"saved {out}: {stats['compressed_bytes']:,} bytes "
          f"({stats['ratio']:.1f}x smaller than bf16 dense)")

    # 4) re-load and verify quality
    taus = capi.load(out).as_path_dict("dense")
    from repro.peft.lora import _path_str
    flat, tdef = jax.tree_util.tree_flatten_with_path(lora0)
    lora_hat = jax.tree_util.tree_unflatten(tdef, [
        (l.astype(jnp.float32)
         + jnp.asarray(taus[_path_str(p)], jnp.float32).reshape(l.shape)
         ).astype(l.dtype) for p, l in flat])

    for name, lp in (("base (no expert)", lora0), ("fine-tuned", lora),
                     ("ComPEFT reconstructed", lora_hat)):
        l = eval_loss(api, apply_lora(base, lp, lcfg_l), RT, cfg, args.task,
                      n_batches=2, seq_len=48, global_batch=8)
        print(f"  eval[{name:24s}]: {l:.4f}")
    print("OK")


if __name__ == "__main__":
    main()
