"""Fetching experts over the network — the claim ComPEFT is named for.

A publisher host compresses an expert and publishes it through a
transport backend as one checksummed wire blob; a consumer host builds an
``ExpertRegistry`` over that transport and serves the expert without ever
seeing a dense checkpoint.  The link here is simulated (configurable
bandwidth/latency), so the run is reproducible anywhere; swap in
``LocalTransport`` (shared filesystem) or ``HTTPTransport`` (any static
file server) without touching the serving code.

    PYTHONPATH=src python examples/remote_experts.py [--density 0.1]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api as capi
from repro.configs import get_smoke_config
from repro.expert import GOLOMB, PACKED
from repro.models import Runtime, build
from repro.serve import Request, uncompressed_baseline_bytes
from repro.transport import SimulatedNetworkTransport

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--bandwidth-mbps", type=float, default=16.0,
                    help="simulated link bandwidth (megabits/s)")
    ap.add_argument("--latency-ms", type=float, default=40.0)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    model = build(cfg)
    base = model.init(jax.random.PRNGKey(0))

    # ---- publisher host: compress fine-tunes, publish wire blobs --------
    transport = SimulatedNetworkTransport(
        bandwidth_bps=args.bandwidth_mbps * 1e6 / 8,
        latency_s=args.latency_ms / 1e3, seed=0)
    local_experts = []
    for i in range(2):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        ex = capi.compress(base, ft, name=f"expert{i}",
                           density=args.density, alpha=1.0)
        local_experts.append(ex)
        pub = capi.publish(ex, transport, rep=GOLOMB)
        dense = uncompressed_baseline_bytes(ex)
        print(f"published {pub['name']}: {pub['nbytes']:,} B on the wire "
              f"vs {dense:,} B dense bf16 ({dense / pub['nbytes']:.1f}x)")

    # ---- consumer host: a registry over the remote store ----------------
    registry = capi.registry(transport=transport)
    engine = capi.serve(model, RT, base, registry, max_batch=4,
                        cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 12),
                                       jnp.int32),
                    max_new_tokens=4)
            for i in range(4)]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    print(f"served {len(reqs)} requests over the simulated link in "
          f"{dt:.1f}s; tokens: {[r.out_tokens for r in reqs]}")

    s = engine.swap_summary()
    print(f"remote fetches: {s['remote_fetches']} "
          f"({s['remote_bytes']:,} B on the wire, "
          f"{s['remote_seconds']*1e3:.0f} ms in transfer+decode, "
          f"prefetch hits: {s['prefetch_hits']})")

    # fetched experts are bit-identical to the publisher's local planes
    for ex in local_experts:
        got = registry.get(ex.name).packed
        for p, pt in ex.packed.items():
            assert (np.asarray(pt.pos) == np.asarray(got[p].pos)).all()
            assert (np.asarray(pt.neg) == np.asarray(got[p].neg)).all()
    print("fetched experts bit-identical to published ones; "
          f"wire bytes per expert: {s['remote_bytes'] // 2:,} "
          f"(packed in HBM: {local_experts[0].nbytes(PACKED):,} B)")
    print("OK")


if __name__ == "__main__":
    main()
