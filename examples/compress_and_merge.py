"""Expert algebra on compressed artifacts: Task Arithmetic, TIES merging and
LoraHub-style few-shot composition over ComPEFT ``Expert`` artifacts
(paper §3.6/3.7), through the ``repro.api`` facade.

    PYTHONPATH=src python examples/compress_and_merge.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api as capi
from repro.configs import get_smoke_config
from repro.core.merging import lorahub_search, pairwise_similarity_matrix
from repro.data.pipeline import eval_loss, make_batch_for
from repro.expert import PACKED
from repro.models import Runtime, build
from repro.peft import LoraConfig, apply_lora, init_lora

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def main():
    cfg = get_smoke_config("qwen2_5_3b", d_model=96, n_units=2)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    lcfg = LoraConfig(rank=4, alpha=8.0)

    # train three task experts
    experts = {}
    for task in (1, 2, 3):
        lora0 = init_lora(jax.random.PRNGKey(task), base, lcfg)

        def loss_fn(lp, b):
            return api.loss_and_logits(apply_lora(base, lp, lcfg), b, RT)[0]

        g = jax.jit(jax.grad(loss_fn))
        lora = lora0
        for s in range(40):
            lora = jax.tree_util.tree_map(
                lambda p, gg: p - 0.5 * gg, lora,
                g(lora, make_batch_for(cfg, s, 48, 8, task_id=task)))
        experts[task] = (lora0, lora)
        print(f"expert {task} trained")

    # one Expert artifact per task: tau = lora - lora0, Algorithm 1
    arts = {t: capi.compress(experts[t][0], experts[t][1],
                             name=f"task{t}", kind="lora", density=0.2)
            for t in experts}

    print("\nexpert similarity (popcount cosine):")
    sim = pairwise_similarity_matrix([a.as_(PACKED) for a in arts.values()])
    print(np.round(sim, 3))

    print("\nmerging (lower eval loss on each task is better):")
    merged_ta = capi.merge(list(arts.values()), method="task_arithmetic",
                           lam=0.7)
    merged_ties = capi.merge(list(arts.values()), method="ties",
                             density=0.3, lam=0.7)
    merged_fast = capi.merge(list(arts.values()), method="packed", lam=0.7)
    for name, m in (("task-arithmetic", merged_ta), ("ties", merged_ties),
                    ("packed-TA (bitplane fast path)", merged_fast)):
        losses = []
        for t in experts:
            lora_m = jax.tree_util.tree_map(
                lambda a, d: (a.astype(jnp.float32)
                              + d.astype(jnp.float32)).astype(a.dtype),
                experts[t][0], m)
            losses.append(eval_loss(api, apply_lora(base, lora_m, lcfg), RT,
                                    cfg, t, n_batches=1, seq_len=48,
                                    global_batch=8))
        print(f"  {name:32s}: avg loss {np.mean(losses):.4f}")

    print("\nLoraHub few-shot composition for unseen mixture task 100:")
    mods = [arts[t].to_dense_tau() for t in arts]

    def few_shot(tc):
        lora_c = jax.tree_util.tree_map(
            lambda a, d: (a.astype(jnp.float32)
                          + d.astype(jnp.float32)).astype(a.dtype),
            experts[1][0], tc)
        b = make_batch_for(cfg, 0, 48, 9, task_id=100)
        return float(api.loss_and_logits(apply_lora(base, lora_c, lcfg),
                                         b, RT)[0])

    w, best = lorahub_search(mods, few_shot, n_iters=30, seed=0)
    print(f"  weights={np.round(w, 3)} loss={best:.4f} "
          f"(zero-composition={few_shot(jax.tree_util.tree_map(jnp.zeros_like, mods[0])):.4f})")
    print("OK")


if __name__ == "__main__":
    main()
