"""Quickstart: ComPEFT in 60 seconds, through the ``repro.api`` facade.

    PYTHONPATH=src python examples/quickstart.py

One ``Expert`` artifact moves across the whole representation lattice —
DENSE (task vector) -> TERNARY -> PACKED (2-bit bitplanes) -> GOLOMB
(wire format) — with storage accounting at every stop, plus the bitwise
expert-similarity ops and a save/load round trip.
"""

import os
import tempfile

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.expert import DENSE, GOLOMB, PACKED, TERNARY
from repro.core.ternary_ops import cosine_similarity, scaled_dot


def main():
    rng = np.random.default_rng(0)
    # a fake fine-tuning residual: near-zero Gaussian (paper App. B.4)
    tau = {"layer0/wq": jnp.asarray(rng.normal(0, 7e-4, (512, 512)),
                                    jnp.float32),
           "layer0/wo": jnp.asarray(rng.normal(0, 7e-4, (512, 512)),
                                    jnp.float32)}

    print("== Algorithm 1: sparsify + ternary-quantize (k=5%, alpha=1) ==")
    ex = api.compress(tau, name="quickstart", density=0.05, alpha=1.0)
    s = ex.summary()
    print(f"  params            : {s['n_params']:,}")
    print(f"  surviving (nnz)   : {s['nnz']:,}  (density {s['density']:.3f})")
    print(f"  dense bf16        : {s['dense_bits']/8/1024:.1f} KiB")
    print(f"  entropy bound     : {s['entropy_bits']/8/1024:.1f} KiB "
          f"({s['compression_x_entropy']:.1f}x)")
    print(f"  bitplane (compute): {s['bitplane_bits']/8/1024:.1f} KiB "
          f"({s['compression_x_bitplane']:.1f}x)")
    print(f"  reconstruction err: {s['rel_recon_err']:.3f} (relative)")

    print("\n== Representation lattice (one artifact, four forms) ==")
    for rep in (DENSE, TERNARY, PACKED, GOLOMB):
        print(f"  nbytes({rep:7s})   : {ex.nbytes(rep):,}")

    print("\n== Golomb round trip (storage format) ==")
    out = os.path.join(tempfile.gettempdir(), "quickstart_expert.npz")
    stats = ex.save(out)
    back = api.load(out)
    pt, bpt = ex.packed["layer0/wq"], back.packed["layer0/wq"]
    assert (np.asarray(pt.pos) == np.asarray(bpt.pos)).all()
    assert (np.asarray(pt.neg) == np.asarray(bpt.neg)).all()
    print(f"  saved {out}: {stats['compressed_bytes']:,} bytes "
          f"({stats['ratio']:.1f}x vs bf16); save/load round-trip exact")

    print("\n== Bitwise expert algebra (AND/XOR + POPCNT) ==")
    a = ex.packed["layer0/wq"]
    print(f"  packed bytes       : {ex.nbytes(PACKED):,}")
    print(f"  self cosine        : {float(cosine_similarity(a, a)):.3f}")
    print(f"  self scaled dot    : {float(scaled_dot(a, a)):.3e}")

    print("\n== Reconstruct -> dense delta ==")
    dense = ex.to_dense_tau()
    vals = np.unique(np.asarray(dense["layer0/wq"]))
    print(f"  unique values in reconstructed leaf: {vals}")
    print("\nOK")


if __name__ == "__main__":
    main()
