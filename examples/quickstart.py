"""Quickstart: ComPEFT in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Compresses a task vector with Algorithm 1, shows the storage accounting
(entropy / Golomb / bitplanes), round-trips the Golomb codec, and runs the
bitwise expert-similarity ops.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CompressionConfig, compress, compression_summary,
                        decompress, pack_tree, tree_packed_bytes)
from repro.core.golomb import decode, encode
from repro.core.ternary_ops import cosine_similarity, scaled_dot


def main():
    rng = np.random.default_rng(0)
    # a fake fine-tuning residual: near-zero Gaussian (paper App. B.4)
    tau = {"layer0/wq": jnp.asarray(rng.normal(0, 7e-4, (512, 512)),
                                    jnp.float32),
           "layer0/wo": jnp.asarray(rng.normal(0, 7e-4, (512, 512)),
                                    jnp.float32)}

    print("== Algorithm 1: sparsify + ternary-quantize (k=5%, alpha=1) ==")
    comp = compress(tau, CompressionConfig(density=0.05, alpha=1.0))
    s = compression_summary(tau, comp)
    print(f"  params            : {s['n_params']:,}")
    print(f"  surviving (nnz)   : {s['nnz']:,}  (density {s['density']:.3f})")
    print(f"  dense bf16        : {s['dense_bits']/8/1024:.1f} KiB")
    print(f"  entropy bound     : {s['entropy_bits']/8/1024:.1f} KiB "
          f"({s['compression_x_entropy']:.1f}x)")
    print(f"  bitplane (compute): {s['bitplane_bits']/8/1024:.1f} KiB "
          f"({s['compression_x_bitplane']:.1f}x)")
    print(f"  reconstruction err: {s['rel_recon_err']:.3f} (relative)")

    print("\n== Golomb codec round-trip (storage format) ==")
    leaf = comp["layer0/wq"]
    blob = encode(np.asarray(leaf.signs), float(leaf.scale))
    back, scale = decode(blob)
    assert (back == np.asarray(leaf.signs).reshape(-1)).all()
    print(f"  encoded {leaf.signs.size:,} ternary values -> {len(blob):,} "
          f"bytes (exact round-trip OK)")

    print("\n== Bitwise expert algebra (AND/XOR + POPCNT) ==")
    packed = pack_tree(comp)
    a = packed["layer0/wq"]
    print(f"  packed bytes       : {tree_packed_bytes(packed):,}")
    print(f"  self cosine        : {float(cosine_similarity(a, a)):.3f}")
    print(f"  self scaled dot    : {float(scaled_dot(a, a)):.3e}")

    print("\n== Decompress -> dense delta ==")
    dense = decompress(comp)
    vals = np.unique(np.asarray(dense['layer0/wq']))
    print(f"  unique values in reconstructed leaf: {vals}")
    print("\nOK")


if __name__ == "__main__":
    main()
